"""Training substrate tests: optimizer, checkpoint atomicity, failure/restart
equivalence, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mace import MaceConfig
from repro.data.molecules import SyntheticCFMDataset
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.compression import int8_compress_decompress, make_error_feedback
from repro.train.optimizer import (
    EMA,
    adamw,
    apply_updates,
    chain,
    clip_by_global_norm,
    warmup_cosine_lr,
)
from repro.train.train_loop import Trainer, TrainerConfig

TINY = MaceConfig(
    n_species=10, channels=4, hidden_ls=(0, 1), sh_lmax=2, a_ls=(0, 1, 2),
    correlation=2, n_interactions=2, avg_num_neighbors=8.0, impl="fused",
)


def test_adamw_converges_quadratic():
    opt = adamw(0.1)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for i in range(200):
        grads = {"x": 2 * params["x"]}
        upd, state = opt.update(grads, state, params, jnp.asarray(i))
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_clip_and_chain():
    opt = chain(clip_by_global_norm(1.0), adamw(0.1))
    params = {"x": jnp.asarray([1.0])}
    state = opt.init(params)
    upd, state = opt.update({"x": jnp.asarray([1e6])}, state, params, jnp.asarray(0))
    assert np.isfinite(float(upd["x"][0]))


def test_warmup_cosine_schedule():
    s = warmup_cosine_lr(1.0, warmup=10, total=100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < 1e-6


def test_ema_tracks_params():
    e = EMA(0.9)
    p = {"w": jnp.zeros(3)}
    ep = e.init(p)
    p2 = {"w": jnp.ones(3)}
    for step in range(50):
        ep = e.update(ep, p2, jnp.asarray(step))
    assert float(jnp.abs(ep["w"] - 1.0).max()) < 0.1


def test_checkpoint_roundtrip_and_retention(tmp_path):
    d = str(tmp_path / "ckpt")
    state = {"a": jnp.arange(5, dtype=jnp.float32), "n": {"b": jnp.ones((2, 2))}}
    for s in (10, 20, 30, 40):
        save_checkpoint(d, s, state, meta={"tag": s}, keep=2)
    assert latest_step(d) == 40
    # retention: only 2 newest kept
    kept = [n for n in os.listdir(d) if n.startswith("step_")]
    assert len(kept) == 2
    step, restored, meta = restore_checkpoint(d, state)
    assert step == 40 and meta["tag"] == 40
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))


def test_checkpoint_ignores_uncommitted(tmp_path):
    d = str(tmp_path / "ckpt")
    state = {"a": jnp.zeros(2)}
    save_checkpoint(d, 1, state)
    # fake a crashed (uncommitted) newer checkpoint
    os.makedirs(os.path.join(d, "step_0000000099"))
    assert latest_step(d) == 1


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"a": jnp.zeros(3)})


def test_int8_compression_bounded_error():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)) * 3)
    g_hat, r = int8_compress_decompress(g)
    assert float(jnp.abs(r).max()) <= float(jnp.max(jnp.abs(g))) / 127.0 + 1e-6
    np.testing.assert_allclose(np.asarray(g_hat + r), np.asarray(g), rtol=1e-6)


def test_error_feedback_reduces_bias():
    """With error feedback the *accumulated* compressed signal tracks the
    accumulated true gradient (residual stays bounded)."""
    init, compress = make_error_feedback()
    g = {"w": jnp.full((100,), 0.003)}  # tiny grads: naive int8 rounds to 0
    r = init(g)
    total = jnp.zeros(100)
    for _ in range(50):
        g_hat, r = compress(g, r)
        total = total + g_hat["w"]
    want = 0.003 * 50
    np.testing.assert_allclose(np.asarray(total), want, rtol=0.05)


@pytest.mark.slow
def test_trainer_runs_and_checkpoints(tmp_path):
    ds = SyntheticCFMDataset(64, seed=0, max_atoms=96)
    tcfg = TrainerConfig(
        capacity=128, edge_factor=48, max_graphs=16, lr=2e-3,
        ckpt_dir=str(tmp_path / "run"), ckpt_every=4, log_every=1000,
    )
    tr = Trainer(TINY, tcfg, ds, seed=0)
    out = tr.train(n_epochs=1, max_steps=8)
    losses = [h["loss"] for h in out["history"]]
    assert len(losses) == 8
    assert all(np.isfinite(losses))
    assert latest_step(tcfg.ckpt_dir) == 8


@pytest.mark.slow
def test_single_batch_overfit():
    """Train repeatedly on ONE batch through the engine API: loss must drop
    hard (step mechanics + optimizer + grads all correct end-to-end)."""
    import jax.numpy as jnp

    ds = SyntheticCFMDataset(8, seed=0, max_atoms=48)
    tcfg = TrainerConfig(capacity=128, edge_factor=48, max_graphs=16, lr=5e-3)
    tr = Trainer(TINY, tcfg, ds, seed=0)
    bin_items = tr.sampler.bins_for_epoch(0)[0]
    batch, _ = tr.engine.collate(
        [[ds.get(i) for i in bin_items]], tr.bin_shape
    )
    losses = []
    for i in range(40):
        tr.params, tr.opt_state, tr.ef_state, m = tr.engine.step(
            tr.params, tr.opt_state, tr.ef_state, batch, jnp.asarray(i)
        )
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.5 * losses[0], losses[::8]


@pytest.mark.slow
def test_failure_restart_equivalence(tmp_path):
    """Kill at step 4, restart from checkpoint, and verify the final params
    equal an uninterrupted run (bitwise determinism of the whole substrate)."""
    ds = SyntheticCFMDataset(64, seed=1, max_atoms=96)

    def cfg(d):
        return TrainerConfig(
            capacity=128, edge_factor=48, max_graphs=16,
            ckpt_dir=str(tmp_path / d), ckpt_every=2,
        )

    ref = Trainer(TINY, cfg("ref"), ds, seed=3)
    ref.train(n_epochs=1, max_steps=6)

    crash = Trainer(TINY, cfg("crash"), ds, seed=3)
    with pytest.raises(RuntimeError):
        crash.train(n_epochs=1, max_steps=6, simulate_failure_at=4)

    resumed = Trainer(TINY, cfg("crash"), ds, seed=3)
    assert resumed.maybe_restore()
    # failure hit *before* the step-4 checkpoint committed -> resume from 2
    # and deterministically replay steps 3-4 (same bins, same batches).
    assert resumed.global_step == 2
    resumed.train(n_epochs=1, max_steps=6)

    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)
