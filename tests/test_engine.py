"""Execution-engine tests: kernel registry, stacked collation, and the
Sequential vs ShardMap equivalence proof on a forced 2-device CPU mesh.

The multi-device half runs in a subprocess (same pattern as
test_dryrun_small) because ``--xla_force_host_platform_device_count`` must
be set before the first jax import and the main pytest process keeps its
single device.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.binpack import Bins, balance_metrics
from repro.core.irreps import lspec, sh_spec
from repro.core.channelwise_tp import TPSpec
from repro.core.mace import MaceConfig
from repro.core.symmetric_contraction import SymConSpec, init_symcon_weights
from repro.data.collate import BinShape, collate_bin, collate_stacked
from repro.data.molecules import SyntheticCFMDataset
from repro.kernels import registry
from repro.train.engine import RankTelemetry, make_engine
from repro.train.train_loop import Trainer, TrainerConfig

TINY = MaceConfig(
    n_species=10, channels=4, hidden_ls=(0, 1), sh_lmax=2, a_ls=(0, 1, 2),
    correlation=2, n_interactions=2, avg_num_neighbors=8.0, impl="fused",
)


# ---------------------------------------------------------------------------
# kernel registry
# ---------------------------------------------------------------------------


def test_registry_lists_builtin_impls():
    for kind in ("symcon", "channelwise_tp"):
        names = registry.available(kind)
        assert {"ref", "fused", "pallas"} <= set(names)
    # capability filter: pallas is TPU-native, interpret-mode on cpu
    assert "pallas" in registry.available("symcon", platform="cpu")
    impl = registry.get_impl("symcon", "pallas")
    assert impl.platforms == ("tpu",) and "cpu" in impl.interpret_only_on


def test_registry_unknown_name_and_kind():
    with pytest.raises(KeyError):
        registry.get_impl("symcon", "no_such_impl")
    with pytest.raises(KeyError):
        registry.canonical_kind("no_such_kind")
    # aliases resolve
    assert registry.canonical_kind("tp") == "channelwise_tp"


def test_registry_ref_fused_agree():
    spec = SymConSpec(lspec(0, 1, 2), lspec(0, 1), 2)
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (16, 4, spec.in_spec.dim))
    species = jax.random.randint(key, (16,), 0, 4)
    W = init_symcon_weights(key, spec, 4, 4)
    ref = registry.resolve("symcon", "ref", spec)
    fused = registry.resolve("symcon", "fused", spec)
    np.testing.assert_allclose(
        np.asarray(ref(A, species, W)), np.asarray(fused(A, species, W)),
        rtol=1e-4, atol=1e-4,
    )
    tspec = TPSpec(sh_spec(2), lspec(0, 1), lspec(0, 1, 2))
    Y = jax.random.normal(key, (32, tspec.y_spec.dim))
    h = jax.random.normal(key, (32, 4, tspec.h_spec.dim))
    R = jax.random.normal(key, (32, tspec.n_paths, 4))
    np.testing.assert_allclose(
        np.asarray(registry.resolve("channelwise_tp", "ref", tspec)(Y, h, R)),
        np.asarray(registry.resolve("channelwise_tp", "fused", tspec)(Y, h, R)),
        rtol=1e-4, atol=1e-4,
    )


def test_registry_resolve_is_memoised():
    spec = SymConSpec(lspec(0, 1), lspec(0, 1), 2)
    assert registry.resolve("symcon", "fused", spec) is registry.resolve(
        "symcon", "fused", spec
    )


def test_registry_register_hook_roundtrip():
    calls = []

    @registry.register("symcon", "custom_test_impl", platforms=("cpu",),
                       description="test-only")
    def _build(spec):
        calls.append(spec)
        return lambda A, species, W: A

    try:
        assert "custom_test_impl" in registry.available("symcon")
        spec = SymConSpec(lspec(0, 1), lspec(0, 1), 2)
        fn = registry.resolve("symcon", "custom_test_impl", spec)
        A = jnp.ones((2, 4, spec.in_spec.dim))
        assert fn(A, None, None) is A
        assert calls == [spec]
        # duplicate registration without overwrite is an error
        with pytest.raises(ValueError):
            registry.register("symcon", "custom_test_impl")(lambda s: None)
    finally:
        registry.unregister("symcon", "custom_test_impl")
    assert "custom_test_impl" not in registry.available("symcon")


# ---------------------------------------------------------------------------
# stacked collation + telemetry plumbing
# ---------------------------------------------------------------------------


def test_collate_stacked_layout():
    ds = SyntheticCFMDataset(8, seed=0, max_atoms=24)
    shape = BinShape.for_capacity(48, 24, 8)
    mols_per_rank = [[ds.get(0), ds.get(1)], [ds.get(2)], [ds.get(3)]]
    stacked = collate_stacked(mols_per_rank, shape)
    single = collate_bin(mols_per_rank[1], shape)
    for k, v in stacked.items():
        assert v.shape[0] == 3, k
        np.testing.assert_array_equal(v[1], single[k])
    with pytest.raises(ValueError):
        collate_stacked([], shape)


def test_balance_metrics_accepts_measured_work():
    b = Bins([[0], [1], [2], [3]], [10, 10, 10, 10], capacity=16)
    proxy = balance_metrics(b, 2)
    assert not proxy.measured and proxy.straggler_ratio == pytest.approx(1.0)
    # measured telemetry says rank 1 is 3x slower -> straggler 1.5
    measured = balance_metrics(
        b, 2, measured_work=np.array([[1.0, 3.0], [1.0, 3.0]])
    )
    assert measured.measured
    assert measured.straggler_ratio == pytest.approx(1.5)
    with pytest.raises(ValueError):
        balance_metrics(b, 2, measured_work=np.ones(4))


def test_rank_telemetry_matrices():
    t = RankTelemetry(2)
    t.record([1.0, 2.0], [100, 200])
    t.record([2.0, 2.0], [200, 200])
    assert t.work_matrix().shape == (2, 2)
    assert t.c_token() == pytest.approx(7.0 / 700.0)
    assert t.measured_straggler() == pytest.approx((2.0 / 1.5 + 1.0) / 2)
    # skip drops the jit-compiling warmup step from the calibration
    assert t.c_token(skip=1) == pytest.approx(4.0 / 400.0)
    assert t.measured_straggler(skip=1) == pytest.approx(1.0)
    # per-rank-timed engine: straggler work = times
    np.testing.assert_array_equal(t.straggler_matrix(), t.work_matrix())
    # lock-step engine (shard_map): identical times are vacuous, so the
    # straggler model falls back to the measured per-rank loads
    ls = RankTelemetry(2, lockstep=True)
    ls.record([3.0, 3.0], [100, 300])
    np.testing.assert_array_equal(ls.straggler_matrix(), ls.load_matrix())
    assert ls.measured_straggler() == pytest.approx(1.5)
    # lock-step wall is gated by the straggler: divide by max load, not sum
    assert ls.c_token() == pytest.approx(3.0 / 300.0)


def test_make_engine_unknown_name():
    with pytest.raises(KeyError):
        make_engine("warp_drive", TINY, TrainerConfig(), None, 8)


# ---------------------------------------------------------------------------
# engine equivalence
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_engines_match_on_single_device_mesh():
    """shard_map on a 1-device ("data",) mesh reproduces the sequential
    oracle in-process (the 2-device proof runs in the subprocess test)."""
    ds = SyntheticCFMDataset(24, seed=0, max_atoms=32)
    kw = dict(capacity=48, edge_factor=48, max_graphs=8, lr=2e-3,
              n_ranks=1, ckpt_dir=None)
    tr1 = Trainer(TINY, TrainerConfig(engine="sequential", **kw), ds, seed=0)
    o1 = tr1.train(n_epochs=1, max_steps=5)
    tr2 = Trainer(TINY, TrainerConfig(engine="shard_map", **kw), ds, seed=0)
    o2 = tr2.train(n_epochs=1, max_steps=5)
    np.testing.assert_allclose(
        [h["loss"] for h in o1["history"]],
        [h["loss"] for h in o2["history"]], rtol=1e-5,
    )
    for a, b in zip(jax.tree.leaves(tr1.params), jax.tree.leaves(tr2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    assert tr1.engine.telemetry.n_steps == 5
    assert tr2.engine.telemetry.load_matrix().shape == (5, 1)


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np, jax
from repro.core.mace import MaceConfig
from repro.data.molecules import SyntheticCFMDataset
from repro.train.train_loop import Trainer, TrainerConfig

TINY = MaceConfig(n_species=10, channels=4, hidden_ls=(0, 1), sh_lmax=2,
                  a_ls=(0, 1, 2), correlation=2, n_interactions=2,
                  avg_num_neighbors=8.0, impl="fused")
ds = SyntheticCFMDataset(48, seed=0, max_atoms=48)
out = {"devices": len(jax.devices())}
for compress in (False, True):
    kw = dict(capacity=64, edge_factor=48, max_graphs=8, lr=2e-3, n_ranks=2,
              compress_grads=compress, ckpt_dir=None)
    seq = Trainer(TINY, TrainerConfig(engine="sequential", **kw), ds, seed=0)
    o1 = seq.train(n_epochs=1, max_steps=6)
    smp = Trainer(TINY, TrainerConfig(engine="shard_map", **kw), ds, seed=0)
    o2 = smp.train(n_epochs=1, max_steps=6)
    l1 = [h["loss"] for h in o1["history"]]
    l2 = [h["loss"] for h in o2["history"]]
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    # compressed path: a one-quantum round() flip near a quantization
    # boundary shifts a param by ~scale/R, so give it headroom
    rtol, atol = (1e-4, 2e-5) if compress else (2e-5, 1e-6)
    for a, b in zip(jax.tree.leaves(seq.params), jax.tree.leaves(smp.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol)
    # residuals accumulate on every leaf with a live gradient (the last
    # layer's l=1 block is a dead end -> legitimately zero-grad leaves)
    ef_live = bool(compress) and any(
        float(np.abs(np.asarray(e)).max()) > 0
        for e in jax.tree.leaves(smp.ef_state)
    ) and any(
        float(np.abs(np.asarray(e)).max()) > 0
        for e in jax.tree.leaves(seq.ef_state)
    )
    out[f"compress_{compress}"] = {
        "steps": len(l1),
        "losses_finite": bool(np.all(np.isfinite(l1))),
        "seq_straggler": seq.engine.telemetry.measured_straggler(skip=1),
        "smp_loads": smp.engine.telemetry.load_matrix().sum(axis=0).tolist(),
        "ef_live": ef_live,
    }
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_shard_map_matches_sequential_two_devices():
    """Acceptance proof: on a real 2-device CPU mesh, ShardMapEngine
    reproduces SequentialEngine losses and params (allclose) over 6 steps,
    plain and int8-compressed all-reduce both."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["devices"] == 2
    for key in ("compress_False", "compress_True"):
        assert out[key]["steps"] >= 5
        assert out[key]["losses_finite"]
        # both ranks actually consumed work
        assert all(l > 0 for l in out[key]["smp_loads"])
    # error feedback accumulated nonzero residuals on every rank, and the
    # two backends' residuals matched (implied by param allclose over steps)
    assert out["compress_True"]["ef_live"]
