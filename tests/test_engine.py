"""Execution-engine tests: kernel registry, stacked collation, telemetry
summaries, and the engine-equivalence harness — Sequential vs ShardMap,
inline vs async-prefetched, plain vs int8-compressed all-reduce — on a
forced 2-device CPU mesh.

The multi-device half runs in a subprocess (same pattern as
test_dryrun_small) because ``--xla_force_host_platform_device_count`` must
be set before the first jax import and the main pytest process keeps its
single device.  The subprocess runs the whole (engine x prefetch-depth)
matrix against one non-prefetched SequentialEngine oracle so each
parametrized compress case pays the interpreter/jax startup once.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.binpack import Bins, balance_metrics
from repro.core.irreps import lspec, sh_spec
from repro.core.channelwise_tp import TPSpec
from repro.core.mace import MaceConfig
from repro.core.symmetric_contraction import SymConSpec, init_symcon_weights
from repro.data.collate import BinShape, collate_bin, collate_stacked
from repro.data.molecules import SyntheticCFMDataset
from repro.kernels import registry
from repro.train.engine import MergedTelemetry, RankTelemetry, make_engine
from repro.train.train_loop import Trainer, TrainerConfig

TINY = MaceConfig(
    n_species=10, channels=4, hidden_ls=(0, 1), sh_lmax=2, a_ls=(0, 1, 2),
    correlation=2, n_interactions=2, avg_num_neighbors=8.0, impl="fused",
)


# ---------------------------------------------------------------------------
# kernel registry
# ---------------------------------------------------------------------------


def test_registry_lists_builtin_impls():
    for kind in ("symcon", "channelwise_tp", "interaction"):
        names = registry.available(kind)
        assert {"ref", "fused", "pallas"} <= set(names)
    # capability filter: pallas is TPU-native, interpret-mode on cpu
    assert "pallas" in registry.available("symcon", platform="cpu")
    impl = registry.get_impl("symcon", "pallas")
    assert impl.platforms == ("tpu",) and "cpu" in impl.interpret_only_on
    # every built-in pallas impl ships a hand-written backward, and the
    # capabilities() table reports it
    for kind in ("symcon", "channelwise_tp", "interaction"):
        assert registry.capabilities(kind)["pallas"]["has_custom_bwd"]


def test_registry_unknown_name_and_kind():
    with pytest.raises(KeyError):
        registry.get_impl("symcon", "no_such_impl")
    with pytest.raises(KeyError):
        registry.canonical_kind("no_such_kind")
    # aliases resolve
    assert registry.canonical_kind("tp") == "channelwise_tp"


def test_registry_ref_fused_agree():
    spec = SymConSpec(lspec(0, 1, 2), lspec(0, 1), 2)
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (16, 4, spec.in_spec.dim))
    species = jax.random.randint(key, (16,), 0, 4)
    W = init_symcon_weights(key, spec, 4, 4)
    ref = registry.resolve("symcon", "ref", spec)
    fused = registry.resolve("symcon", "fused", spec)
    np.testing.assert_allclose(
        np.asarray(ref(A, species, W)), np.asarray(fused(A, species, W)),
        rtol=1e-4, atol=1e-4,
    )
    tspec = TPSpec(sh_spec(2), lspec(0, 1), lspec(0, 1, 2))
    Y = jax.random.normal(key, (32, tspec.y_spec.dim))
    h = jax.random.normal(key, (32, 4, tspec.h_spec.dim))
    R = jax.random.normal(key, (32, tspec.n_paths, 4))
    np.testing.assert_allclose(
        np.asarray(registry.resolve("channelwise_tp", "ref", tspec)(Y, h, R)),
        np.asarray(registry.resolve("channelwise_tp", "fused", tspec)(Y, h, R)),
        rtol=1e-4, atol=1e-4,
    )


def test_registry_resolve_is_memoised():
    spec = SymConSpec(lspec(0, 1), lspec(0, 1), 2)
    assert registry.resolve("symcon", "fused", spec) is registry.resolve(
        "symcon", "fused", spec
    )


def test_registry_register_hook_roundtrip():
    calls = []

    @registry.register("symcon", "custom_test_impl", platforms=("cpu",),
                       description="test-only")
    def _build(spec):
        calls.append(spec)
        return lambda A, species, W: A

    try:
        assert "custom_test_impl" in registry.available("symcon")
        spec = SymConSpec(lspec(0, 1), lspec(0, 1), 2)
        fn = registry.resolve("symcon", "custom_test_impl", spec)
        A = jnp.ones((2, 4, spec.in_spec.dim))
        assert fn(A, None, None) is A
        assert calls == [spec]
        # duplicate registration without overwrite is an error
        with pytest.raises(ValueError):
            registry.register("symcon", "custom_test_impl")(lambda s: None)
    finally:
        registry.unregister("symcon", "custom_test_impl")
    assert "custom_test_impl" not in registry.available("symcon")


# ---------------------------------------------------------------------------
# stacked collation + telemetry plumbing
# ---------------------------------------------------------------------------


def test_collate_stacked_layout():
    ds = SyntheticCFMDataset(8, seed=0, max_atoms=24)
    shape = BinShape.for_capacity(48, 24, 8)
    mols_per_rank = [[ds.get(0), ds.get(1)], [ds.get(2)], [ds.get(3)]]
    stacked = collate_stacked(mols_per_rank, shape)
    single = collate_bin(mols_per_rank[1], shape)
    for k, v in stacked.items():
        assert v.shape[0] == 3, k
        np.testing.assert_array_equal(v[1], single[k])
    with pytest.raises(ValueError):
        collate_stacked([], shape)


def test_balance_metrics_accepts_measured_work():
    b = Bins([[0], [1], [2], [3]], [10, 10, 10, 10], capacity=16)
    proxy = balance_metrics(b, 2)
    assert not proxy.measured and proxy.straggler_ratio == pytest.approx(1.0)
    # measured telemetry says rank 1 is 3x slower -> straggler 1.5
    measured = balance_metrics(
        b, 2, measured_work=np.array([[1.0, 3.0], [1.0, 3.0]])
    )
    assert measured.measured
    assert measured.straggler_ratio == pytest.approx(1.5)
    with pytest.raises(ValueError):
        balance_metrics(b, 2, measured_work=np.ones(4))


def test_rank_telemetry_empty_and_validation():
    t = RankTelemetry(3)
    assert t.n_steps == 0
    assert t.work_matrix().shape == (0, 3)
    assert t.load_matrix().shape == (0, 3)
    assert t.straggler_matrix().shape == (0, 3)
    # empty summaries degrade to neutral values, not errors
    assert t.c_token() == 0.0
    assert t.measured_straggler() == 1.0
    # a record must cover every rank
    with pytest.raises(AssertionError):
        t.record([1.0, 2.0], [1, 2, 3])
    with pytest.raises(AssertionError):
        t.record([1.0, 2.0, 3.0], [1, 2])
    # skip past the recorded steps -> empty matrices again
    t.record([1.0, 1.0, 1.0], [1, 1, 1])
    assert t.straggler_matrix(skip=5).shape == (0, 3)
    assert t.measured_straggler(skip=5) == 1.0


def test_rank_telemetry_matrices():
    t = RankTelemetry(2)
    t.record([1.0, 2.0], [100, 200])
    t.record([2.0, 2.0], [200, 200])
    assert t.work_matrix().shape == (2, 2)
    assert t.c_token() == pytest.approx(7.0 / 700.0)
    assert t.measured_straggler() == pytest.approx((2.0 / 1.5 + 1.0) / 2)
    # skip drops the jit-compiling warmup step from the calibration
    assert t.c_token(skip=1) == pytest.approx(4.0 / 400.0)
    assert t.measured_straggler(skip=1) == pytest.approx(1.0)
    # per-rank-timed engine: straggler work = times
    np.testing.assert_array_equal(t.straggler_matrix(), t.work_matrix())
    # lock-step engine (shard_map): identical times are vacuous, so the
    # straggler model falls back to the measured per-rank loads
    ls = RankTelemetry(2, lockstep=True)
    ls.record([3.0, 3.0], [100, 300])
    np.testing.assert_array_equal(ls.straggler_matrix(), ls.load_matrix())
    assert ls.measured_straggler() == pytest.approx(1.5)
    # lock-step wall is gated by the straggler: divide by max load, not sum
    assert ls.c_token() == pytest.approx(3.0 / 300.0)


def test_rank_telemetry_merged_generations():
    """The multi-generation view: rank counts differ across rescale
    segments, scalar summaries aggregate over the whole run, skip applies
    per generation (each rebuild re-pays the jit on its first step)."""
    g1 = RankTelemetry(2)
    g1.record([9.0, 9.0], [100, 100])   # jit warmup step
    g1.record([1.0, 3.0], [100, 300])
    g1.record_host(0.2, 0.1)
    g1.record_host(0.3, 0.1)
    g2 = RankTelemetry(3, lockstep=True)
    g2.record([8.0, 8.0, 8.0], [100, 100, 100])  # warmup after rebuild
    g2.record([2.0, 2.0, 2.0], [100, 100, 200])
    g2.record_rescale(0.5, 1.5)

    m = RankTelemetry.merged(g1, g2)
    assert isinstance(m, MergedTelemetry)
    assert m.n_generations == 2 and m.n_steps == 4
    # ragged per-generation matrices, not one stacked matrix
    shapes = [w.shape for w in m.work_matrices(skip=1)]
    assert shapes == [(1, 2), (1, 3)]
    assert [s.shape for s in m.straggler_matrices(skip=1)] == [(1, 2), (1, 3)]
    # c_token: (1+3 [seq] + 2 [lockstep wall]) / (400 [seq] + 200 [max load])
    assert m.c_token(skip=1) == pytest.approx(6.0 / 600.0)
    # per-step max/mean: seq step (3/2), lockstep loads step (200/133.3)
    assert m.measured_straggler(skip=1) == pytest.approx(
        (3.0 / 2.0 + 200.0 / (400.0 / 3.0)) / 2
    )
    # host telemetry concatenates (only g1 recorded any)
    assert m.host_matrix().shape == (2, 2)
    assert m.overlap_seconds() == pytest.approx(0.3)
    assert m.rescale_seconds() == (0.5, 1.5)
    # degenerate views stay neutral
    assert m.measured_straggler(skip=5) == 1.0
    assert m.c_token(skip=5) == 0.0
    assert RankTelemetry.merged(g1).n_steps == 2
    with pytest.raises(ValueError):
        RankTelemetry.merged()


def test_trainer_telemetry_property_spans_generations():
    """Trainer.telemetry returns the live engine's telemetry before any
    rescale and a merged view afterwards (bench_scaling's calibration
    source)."""
    ds = SyntheticCFMDataset(8, seed=0, max_atoms=24)
    tcfg = TrainerConfig(capacity=48, edge_factor=48, max_graphs=8,
                         ckpt_dir=None)
    tr = Trainer(TINY, tcfg, ds, seed=0)
    assert tr.telemetry is tr.engine.telemetry
    # simulate a past generation (a full rescale needs a multi-device story;
    # the property only concerns the merge plumbing)
    old = RankTelemetry(2)
    old.record([1.0, 1.0], [10, 10])
    tr.telemetry_generations.append(old)
    merged = tr.telemetry
    assert isinstance(merged, MergedTelemetry)
    assert merged.n_generations == 2


def test_make_engine_unknown_name():
    with pytest.raises(KeyError):
        make_engine("warp_drive", TINY, TrainerConfig(), None, 8)


def test_run_epoch_stops_before_fetching_when_max_steps_reached():
    """Resuming at or past max_steps must not collate (or prefetch) a
    single batch — run_epoch bounds the producer's lookahead by the
    remaining step budget."""
    ds = SyntheticCFMDataset(8, seed=0, max_atoms=24)
    tcfg = TrainerConfig(capacity=48, edge_factor=48, max_graphs=8,
                         prefetch=2, ckpt_dir=None)
    tr = Trainer(TINY, tcfg, ds, seed=0)
    tr.global_step = 5
    fetched = []
    tr._fetch_batch = lambda rank_bins: fetched.append(rank_bins)
    assert tr.run_epoch([], max_steps=3) is True
    assert fetched == []


# ---------------------------------------------------------------------------
# engine equivalence
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_engines_match_on_single_device_mesh():
    """shard_map on a 1-device ("data",) mesh — driven through the async
    prefetch pipeline — reproduces the inline sequential oracle in-process
    (the 2-device matrix proof runs in the subprocess harness)."""
    ds = SyntheticCFMDataset(24, seed=0, max_atoms=32)
    kw = dict(capacity=48, edge_factor=48, max_graphs=8, lr=2e-3,
              n_ranks=1, ckpt_dir=None)
    tr1 = Trainer(
        TINY, TrainerConfig(engine="sequential", prefetch=0, **kw), ds, seed=0
    )
    o1 = tr1.train(n_epochs=1, max_steps=5)
    tr2 = Trainer(
        TINY, TrainerConfig(engine="shard_map", prefetch=1, **kw), ds, seed=0
    )
    o2 = tr2.train(n_epochs=1, max_steps=5)
    np.testing.assert_allclose(
        [h["loss"] for h in o1["history"]],
        [h["loss"] for h in o2["history"]], rtol=1e-5,
    )
    for a, b in zip(jax.tree.leaves(tr1.params), jax.tree.leaves(tr2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    assert tr1.engine.telemetry.n_steps == 5
    assert tr2.engine.telemetry.load_matrix().shape == (5, 1)
    # both loops fed host telemetry through the pipeline; the inline loop
    # can never overlap
    assert len(tr1.engine.telemetry.host_collate) == 5
    assert len(tr2.engine.telemetry.host_collate) == 5
    assert tr1.engine.telemetry.overlap_seconds() == 0.0


# One subprocess per compress setting runs the full (engine x prefetch)
# matrix against a single non-prefetched SequentialEngine oracle.  Variants
# are every combination the trainer exposes except the oracle itself;
# ("shard_map", 0) doubles as the pre-prefetch regression test.
EQUIV_STEPS = 5
EQUIV_VARIANTS = [
    ("sequential", 1), ("sequential", 2),
    ("shard_map", 0), ("shard_map", 1), ("shard_map", 2),
]

SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses, json
import numpy as np, jax
from repro.core.mace import MaceConfig
from repro.data.molecules import SyntheticCFMDataset
from repro.train.train_loop import Trainer, TrainerConfig

cfg = json.loads(sys.argv[1])
compress, steps = cfg["compress"], cfg["steps"]
TINY_KW = dict(n_species=10, channels=4, hidden_ls=(0, 1), sh_lmax=2,
               a_ls=(0, 1, 2), correlation=2, n_interactions=2,
               avg_num_neighbors=8.0, impl="fused")
tcfg_kw = dict(capacity=64, edge_factor=48, max_graphs=8, lr=2e-3, n_ranks=2,
               compress_grads=compress, ckpt_dir=None)
tcfg_kw.update(cfg.get("tcfg", {}))
ds = SyntheticCFMDataset(48, seed=0, max_atoms=48)

def run(engine, prefetch, mace_overrides):
    mcfg = MaceConfig(**{**TINY_KW, **(mace_overrides or {})})
    tr = Trainer(mcfg, TrainerConfig(engine=engine, prefetch=prefetch,
                                     **tcfg_kw), ds, seed=0)
    o = tr.train(n_epochs=1, max_steps=steps)
    return tr, [h["loss"] for h in o["history"]]

def ef_live(tr):
    # residuals accumulate on every leaf with a live gradient (the last
    # layer's l=1 block is a dead end -> legitimately zero-grad leaves)
    return any(float(np.abs(np.asarray(e)).max()) > 0
               for e in jax.tree.leaves(tr.ef_state))

oracle, ref_losses = run("sequential", 0, cfg.get("oracle_mace"))
out = {"devices": len(jax.devices()),
       "oracle": {"steps": len(ref_losses),
                  "losses_finite": bool(np.all(np.isfinite(ref_losses))),
                  "ef_live": bool(compress) and ef_live(oracle)},
       "variants": {}}
# compressed path: a one-quantum round() flip near a quantization
# boundary shifts a param by ~scale/R, so give it headroom
rtol, atol = (1e-4, 2e-5) if compress else (2e-5, 1e-6)
rtol, atol = cfg.get("rtol", rtol), cfg.get("atol", atol)
loss_rtol = cfg.get("loss_rtol", 1e-5)
for engine, depth in cfg["variants"]:
    tr, losses = run(engine, depth, cfg.get("mace"))
    np.testing.assert_allclose(losses, ref_losses, rtol=loss_rtol)
    for a, b in zip(jax.tree.leaves(oracle.params), jax.tree.leaves(tr.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol)
    tel = tr.engine.telemetry
    out["variants"][f"{engine}_p{depth}"] = {
        "steps": len(losses),
        "loads_per_rank": tel.load_matrix().sum(axis=0).tolist(),
        "host_steps": len(tel.host_collate),
        "overlap_s": tel.overlap_seconds(skip=1),
        "block_s": tel.blocking_seconds(),
        "ef_live": bool(compress) and ef_live(tr),
        "resolved_impl": tr.mace_cfg.impl,
        "resolved_interaction": tr.mace_cfg.interaction_impl,
        "autotune": {k: dataclasses.asdict(d)
                     for k, d in tr.autotune_decisions.items()},
    }
print("RESULT " + json.dumps(out))
"""


def run_equivalence_matrix(compress, variants=EQUIV_VARIANTS, steps=EQUIV_STEPS,
                           **cfg_extra):
    """Reusable harness: train the non-prefetched SequentialEngine oracle on
    a forced 2-device CPU mesh, then every (engine, prefetch-depth) variant,
    asserting identical loss curves and allclose final params inside the
    subprocess.  ``cfg_extra`` may override the variant/oracle MaceConfig
    (``mace`` / ``oracle_mace``), TrainerConfig fields (``tcfg``), and the
    comparison tolerances (``rtol``/``atol``/``loss_rtol``) for cross-impl
    matrices.  Returns the telemetry/diagnostics report."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    cfg = {"compress": compress, "steps": steps, "variants": list(variants),
           **cfg_extra}
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, json.dumps(cfg)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["devices"] == 2
    assert out["oracle"]["steps"] == steps >= 3
    assert out["oracle"]["losses_finite"]
    for key, rec in out["variants"].items():
        assert rec["steps"] == steps, key
        # both ranks actually consumed work, every step fed host telemetry
        assert all(l > 0 for l in rec["loads_per_rank"]), key
        assert rec["host_steps"] == steps, key
    return out


@pytest.mark.slow
@pytest.mark.parametrize("compress", [False, True])
def test_engine_prefetch_equivalence_two_devices(compress):
    """Acceptance proof: on a real 2-device CPU mesh, every backend x
    prefetch-depth combination (ShardMap inline/depth-1/depth-2, Sequential
    depth-1/depth-2) reproduces the non-prefetched SequentialEngine oracle's
    losses and params over EQUIV_STEPS steps — plain and int8-compressed
    all-reduce both (the allclose asserts run inside the subprocess)."""
    out = run_equivalence_matrix(compress)
    assert set(out["variants"]) == {
        f"{e}_p{d}" for e, d in EQUIV_VARIANTS
    }
    # overlap_s is reported for diagnosis but not asserted: on a starved CI
    # box the producer may only get scheduled while the consumer already
    # blocks in get(), legitimately measuring ~0.  The deterministic overlap
    # proof (slow consumer => overlap > 0) is
    # tests/test_prefetch.py::test_overlap_measured_when_consumer_is_slow,
    # and the real-training demonstration is bench_scaling --measure-steps.
    assert all(
        rec["overlap_s"] >= 0.0 for rec in out["variants"].values()
    )
    if compress:
        # error feedback accumulated nonzero residuals in oracle and
        # variants (their equality over steps is implied by param allclose)
        assert out["oracle"]["ef_live"]
        assert all(rec["ef_live"] for rec in out["variants"].values())


@pytest.mark.slow
def test_engine_matrix_pallas_interaction_matches_ref_oracle():
    """Acceptance proof for the fused interaction path *including its
    dedicated Pallas backward*: the engine matrix (sequential/shard_map x
    prefetch 0/1) trained with ``interaction_impl="pallas"`` AND
    ``interaction_bwd_impl="pallas"`` (the default; set explicitly here so
    this proof cannot silently drift to the XLA fallback) is allclose to
    the ref-impl non-prefetched SequentialEngine oracle — collation emits
    the pre-blocked edge arrays and every training gradient flows through
    the blocked-gather + TP-transpose backward kernel.  Cross-impl
    tolerances: the kernels reassociate float32 sums, so exact bitwise
    equality is not expected — but 3 optimizer steps must stay within a
    few 1e-3."""
    variants = [("sequential", 0), ("sequential", 1),
                ("shard_map", 0), ("shard_map", 1)]
    out = run_equivalence_matrix(
        compress=False, variants=variants, steps=3,
        mace={"interaction_impl": "pallas",
              "interaction_bwd_impl": "pallas"},
        # oracle differs ONLY in the interaction impl (symcon stays fused on
        # both sides), isolating the kernel under test so the tolerance
        # budget covers nothing but its own float32 reassociation
        oracle_mace={"interaction_impl": "ref"},
        tcfg={"edge_factor": 16},          # keep interpret-mode grids small
        loss_rtol=2e-4, rtol=1e-3, atol=1e-5,
    )
    assert set(out["variants"]) == {f"{e}_p{d}" for e, d in variants}
    # every pallas variant paid (and attributed) host blocking time
    assert all(rec["block_s"] > 0.0 for rec in out["variants"].values())


@pytest.mark.slow
def test_engine_matrix_all_pallas_kernels_fwd_and_bwd():
    """Whole-hot-path proof: training with impl="pallas" (symcon forward
    AND its backward kernel) plus interaction_impl="pallas" (fused
    TP+scatter forward AND the blocked backward kernel) — every custom
    compute hot-spot hand-written in both directions — matches the ref
    oracle on the forced 2-device mesh through both engines."""
    variants = [("sequential", 0), ("shard_map", 1)]
    out = run_equivalence_matrix(
        compress=False, variants=variants, steps=3,
        mace={"impl": "pallas", "interaction_impl": "pallas",
              "interaction_bwd_impl": "pallas"},
        oracle_mace={"interaction_impl": "ref"},
        tcfg={"edge_factor": 16},
        loss_rtol=5e-4, rtol=2e-3, atol=2e-5,
    )
    assert set(out["variants"]) == {f"{e}_p{d}" for e, d in variants}
    assert all(rec["block_s"] > 0.0 for rec in out["variants"].values())


@pytest.mark.slow
def test_engine_matrix_autotuned_impl_matches_ref_oracle():
    """Acceptance proof for ``impl="auto"`` end-to-end: the engine matrix
    (sequential/shard_map x prefetch 0/1) trained with BOTH impl sentinels
    on "auto" — so the Trainer resolves symcon/channelwise_tp AND the
    interaction (impl + tile geometry + bwd) from the committed tuning
    table / roofline fallback before building its engine — is allclose to
    the ref-impl non-prefetched SequentialEngine oracle on the forced
    2-device mesh.  Every variant must report the concrete decisions it
    trained with, they must agree across variants (resolution is a pure
    function of config + shape + table), and no "auto" may survive to the
    model config.  Cross-impl tolerances as in the pallas matrices: the
    impls reassociate float32 sums."""
    variants = [("sequential", 0), ("sequential", 1),
                ("shard_map", 0), ("shard_map", 1)]
    out = run_equivalence_matrix(
        compress=False, variants=variants, steps=3,
        mace={"impl": "auto", "interaction_impl": "auto"},
        oracle_mace={"impl": "fused", "interaction_impl": "ref"},
        tcfg={"edge_factor": 16},
        loss_rtol=2e-4, rtol=1e-3, atol=1e-5,
    )
    assert set(out["variants"]) == {f"{e}_p{d}" for e, d in variants}
    recs = list(out["variants"].values())
    for rec in recs:
        assert rec["resolved_impl"] not in ("auto", None)
        assert rec["resolved_interaction"] not in ("auto", None)
        assert set(rec["autotune"]) == {
            "symcon", "channelwise_tp", "interaction"
        }
        for d in rec["autotune"].values():
            assert d["impl"] not in ("auto", None)
            assert d["source"] in ("measured", "roofline")
            assert d["mode"] == "fwd_bwd" and d["platform"] == "cpu"
    # deterministic: every variant resolved to the same decisions
    assert all(rec["autotune"] == recs[0]["autotune"] for rec in recs[1:])
    assert all(rec["resolved_impl"] == recs[0]["resolved_impl"]
               for rec in recs[1:])
