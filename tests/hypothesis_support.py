"""Shared optional-``hypothesis`` shim for the property-based test files.

``hypothesis`` is optional in this repo: when it is installed the real
``given``/``settings``/``strategies`` are re-exported; when it is absent
every ``@given``-decorated test is collected as a no-arg skip stub and the
deterministic tests in the same file still run.  One copy here (instead of
one per test module) so the skip behaviour cannot drift between files.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def settings(**kwargs):
        return lambda f: f

    def given(**kwargs):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass

            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub

        return deco
